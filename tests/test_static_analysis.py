"""repro-lint: the static analysis passes catch their known-bad fixtures
and run clean on the repo itself.

Each pass gets a deliberately broken input — a per-K dispatch where
ragged mode promises one launch, an unmasked ragged kernel, an
oversized-VMEM BlockSpec, a lock-free cross-thread field write — and
must flag it; the whole-repo runs must stay at zero unwaived errors
(that is the CI gate `scripts/lint_repro.py` enforces).
"""
from __future__ import annotations

import functools
import json
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.static.bench_check import (check_bench_file,
                                               check_bench_files,
                                               flatten_metrics,
                                               write_bench_json)
from repro.analysis.static.concurrency_pass import (analyze_paths,
                                                    run_concurrency_pass)
from repro.analysis.static.fixtures import fixture_engine
from repro.analysis.static.jaxpr_pass import (check_dead_lanes,
                                              check_single_launch,
                                              kernel_name, pallas_eqns,
                                              run_jaxpr_pass,
                                              trace_gcn_executor)
from repro.analysis.static.kernel_pass import (check_contract,
                                               contracts_for_class,
                                               run_kernel_pass)
from repro.analysis.static.report import Report
from repro.kernels.ell_spmm import ragged_ell_contract
from repro.kernels.tile_matmul import matmul_contract


def _errors(findings):
    return [f for f in findings if f.severity == "error" and not f.waived]


def _rules(findings):
    return {f.rule for f in _errors(findings)}


# ------------------------------------------------------------- pass 1 -----

class TestJaxprPass:
    def test_repo_clean(self):
        assert _errors(run_jaxpr_pass()) == []

    def test_double_launch_dispatch_caught(self):
        # the legacy per-K dispatch traces one fixed-K launch per
        # distinct K — in ragged mode that is exactly the regression
        # the single-launch rule exists to catch
        engine = fixture_engine(backend="pallas", ell_dispatch="loop")
        closed, h = trace_gcn_executor(engine, "lint-fixture")
        findings = check_single_launch(closed, n_layers=len(h.weights))
        assert "single-launch" in _rules(findings)
        # and the messages name the per-K kernels it traced instead
        assert any("_ell_kernel" in f.message for f in _errors(findings))

    def test_unmasked_kernel_fails_dead_lane_proof(self):
        # the same launch contract as the production ragged kernel, but
        # with the kk < unit_k value mask dropped: the store is no
        # longer provably zero for a dead unit, so the static sentinel
        # proof must reject it
        def unmasked(tile_col_ref, unit_k_ref, cols_ref, vals_ref, b_ref,
                     o_ref, *, kmax):
            del tile_col_ref, unit_k_ref
            b = b_ref[0]
            cols = cols_ref[0]
            vals = vals_ref[0].astype(jnp.float32)
            acc = jnp.zeros((cols.shape[0], b.shape[1]), jnp.float32)
            for kk in range(kmax):
                g = jnp.take(b, cols[:, kk], axis=0)
                acc = acc + vals[:, kk][:, None] * g.astype(jnp.float32)
            o_ref[0] = acc

        u, r, kmax, nct, t, f = 3, 4, 2, 2, 8, 16
        c = ragged_ell_contract(u, r, kmax, nct, t, f, bf=16)
        spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=c["num_scalar_prefetch"], grid=c["grid"],
            in_specs=c["in_specs"], out_specs=c["out_specs"][0])
        call = pl.pallas_call(
            functools.partial(unmasked, kmax=kmax), grid_spec=spec,
            out_shape=jax.ShapeDtypeStruct(c["out_shapes"][0], jnp.float32),
            interpret=True)
        closed = jax.make_jaxpr(call)(
            jnp.zeros(u, jnp.int32), jnp.zeros(u, jnp.int32),
            jnp.zeros((u, r, kmax), jnp.int32),
            jnp.zeros((u, r, kmax), jnp.float32),
            jnp.zeros((nct, t, f), jnp.float32))
        (eqn,) = pallas_eqns(closed)
        findings = check_dead_lanes(eqn)
        assert _rules(findings) == {"sentinel-safety"}

    def test_masked_production_kernel_passes_dead_lane_proof(self):
        engine = fixture_engine(backend="pallas")
        closed, _ = trace_gcn_executor(engine, "lint-fixture")
        ragged = [e for e in pallas_eqns(closed)
                  if "_ragged_ell_kernel" in kernel_name(e)]
        assert ragged, "fixture must trace a ragged launch"
        assert check_dead_lanes(ragged[0]) == []


# ------------------------------------------------------------- pass 2 -----

class TestKernelPass:
    def test_repo_clean(self):
        assert _errors(run_kernel_pass()) == []

    def test_oversized_vmem_blockspec_caught(self):
        # 3 * (2048*2048*4B) * 2 buffers + scratch >> the 16 MiB budget
        bad = matmul_contract(8192, 8192, 8192, bm=2048, bn=2048, bk=2048)
        assert "vmem-budget" in _rules(check_contract(bad))

    def test_default_matmul_contract_fits(self):
        assert _errors(check_contract(matmul_contract(512, 512, 512))) == []

    def test_out_of_range_tile_col_caught(self):
        # a scalar-prefetch tile_col addressing one past the last B tile
        # must trip the grid-corner bounds evaluation
        u, r, kmax, nct, t, f = 4, 8, 3, 2, 8, 32
        c = ragged_ell_contract(u, r, kmax, nct, t, f, bf=32)
        tile_col = np.full((u,), nct, np.int32)          # out of range
        unit_k = np.full((u,), kmax, np.int32)
        findings = check_contract(c, scalar_args=(tile_col, unit_k))
        assert "index-map-bounds" in _rules(findings)

    def test_oversized_buffer_depth_blows_vmem(self):
        # the ragged contract is legal at the default pipeline depth but
        # a runaway buffer_depth multiplies the resident working set
        # past the 16 MiB budget — exactly the candidate class the
        # autotuner must reject before ever timing it
        u, r, kmax, nct, t, f = 6, 8, 5, 3, 64, 32
        scalars = (np.full((u,), nct - 1, np.int32),
                   np.full((u,), kmax, np.int32))
        good = ragged_ell_contract(u, r, kmax, nct, t, f, bf=32)
        assert _errors(check_contract(good, scalar_args=scalars,
                                      backend="tpu")) == []
        bad = ragged_ell_contract(u, r, kmax, nct, t, f, bf=32,
                                  buffer_depth=4096)
        assert "vmem-budget" in _rules(check_contract(
            bad, scalar_args=scalars, backend="tpu"))

    def test_fixture_class_contracts_clean(self):
        engine = fixture_engine()
        h = engine.handle("lint-fixture")
        pairs = contracts_for_class(h.sclass, (48, 32, 128))
        assert pairs, "fixture class must imply at least one ELL contract"
        for contract, scalars in pairs:
            assert _errors(check_contract(contract,
                                          scalar_args=scalars)) == []


# ------------------------------------------------------------- pass 3 -----

RACY_SERVICE = textwrap.dedent("""\
    import threading

    class Svc:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            self._t = threading.Thread(target=self._worker, daemon=True)

        def _worker(self):
            while True:
                self.count += 1{waiver}

        def snapshot(self):
            return {{"count": self.count}}
""")


class TestConcurrencyPass:
    def test_repo_clean(self):
        assert _errors(run_concurrency_pass()) == []

    def test_lock_free_field_write_caught(self, tmp_path):
        mod = tmp_path / "svc.py"
        mod.write_text(RACY_SERVICE.format(waiver=""))
        findings = analyze_paths([mod], entry_classes={"Svc"})
        errs = _errors(findings)
        assert _rules(findings) == {"field-race"}
        assert any("Svc.count" in f.message for f in errs)

    def test_waiver_suppresses_the_race(self, tmp_path):
        mod = tmp_path / "svc.py"
        mod.write_text(RACY_SERVICE.format(
            waiver="  # lint: racy-ok(test counter)"))
        findings = analyze_paths([mod], entry_classes={"Svc"})
        assert _errors(findings) == []
        waived = [f for f in findings if f.waived]
        assert waived and waived[0].waive_reason == "test counter"

    def test_locked_write_is_clean(self, tmp_path):
        mod = tmp_path / "svc.py"
        mod.write_text(textwrap.dedent("""\
            import threading

            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0
                    self._t = threading.Thread(target=self._worker,
                                               daemon=True)

                def _worker(self):
                    with self._lock:
                        self.count += 1

                def snapshot(self):
                    with self._lock:
                        return {"count": self.count}
        """))
        assert _errors(analyze_paths([mod], entry_classes={"Svc"})) == []

    def test_unlocked_histogram_write_caught(self, tmp_path):
        # Known-bad obs fixture: a Histogram-like ring whose worker
        # stores samples without the lock the public snapshot takes.
        # Subscript stores are writes to the lint — this pins that the
        # obs scope extension actually bites on the shape of bug the
        # metrics primitives could regress into.
        mod = tmp_path / "hist.py"
        mod.write_text(textwrap.dedent("""\
            import threading

            class Hist:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.window = [0.0] * 64
                    self.n = 0
                    self._t = threading.Thread(target=self._worker,
                                               daemon=True)

                def _worker(self):
                    while True:
                        self.window[self.n % 64] = 1.0
                        self.n += 1

                def snapshot(self):
                    with self._lock:
                        return {"n": self.n, "window": list(self.window)}
        """))
        findings = analyze_paths([mod], entry_classes={"Hist"})
        errs = _errors(findings)
        assert "field-race" in _rules(findings)
        assert any("Hist.window" in f.message for f in errs)

    def test_obs_dir_in_default_scope(self):
        from repro.analysis.static.concurrency_pass import (LOCK_ORDER,
                                                            SCOPE_DIRS)
        assert "src/repro/obs" in SCOPE_DIRS
        # metric locks are declared leaves: after every component lock
        for comp in ("RequestQueue._lock", "ExecutorCache._lock",
                     "LatencyModel._lock"):
            for leaf in ("Counter._lock", "Histogram._lock",
                         "Tracer._lock"):
                assert LOCK_ORDER.index(comp) < LOCK_ORDER.index(leaf)

    def test_lock_order_inversion_caught(self, tmp_path):
        mod = tmp_path / "inv.py"
        mod.write_text(textwrap.dedent("""\
            import threading

            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._gate = threading.Lock()

                def forward(self):
                    with self._lock:
                        with self._gate:
                            pass

                def backward(self):
                    with self._gate:
                        with self._lock:
                            pass
        """))
        findings = analyze_paths(
            [mod], entry_classes={"Svc"},
            lock_order=("Svc._lock", "Svc._gate"))
        assert "lock-order" in _rules(findings)
        assert any("inversion" in f.message for f in _errors(findings))

    def test_replicas_file_in_default_scope(self):
        from repro.analysis.static.concurrency_pass import (LOCK_ORDER,
                                                            SCOPE_DIRS)
        assert "src/repro/serving/replicas.py" in SCOPE_DIRS
        # The router lock sits between the frontend locks and the
        # per-replica pipeline lock it routes batches into, and above
        # every metric leaf it updates while routing.
        assert (LOCK_ORDER.index("RequestQueue._dispatch_gate")
                < LOCK_ORDER.index("ReplicaSet._lock")
                < LOCK_ORDER.index("DispatchPipeline._lock"))
        for leaf in ("Counter._lock", "CounterFamily._lock",
                     "GaugeFamily._lock"):
            assert LOCK_ORDER.index("ReplicaSet._lock") < LOCK_ORDER.index(leaf)

    def test_scope_file_entry_is_linted_once(self):
        # replicas.py appears in SCOPE_DIRS both via its directory glob
        # and as an explicit file entry; run_concurrency_pass must
        # dedupe rather than double-report (or crash globbing a file).
        from repro.analysis.static.concurrency_pass import (SCOPE_DIRS,
                                                            _repo_root)
        root = _repo_root()
        scoped = set()
        for d in SCOPE_DIRS:
            target = root / d
            if d.endswith(".py"):
                assert target.is_file()
                scoped.add(target)
            else:
                scoped.update(target.glob("*.py"))
        assert root / "src/repro/serving/replicas.py" in scoped

    def test_unlocked_replica_depth_read_caught(self, tmp_path):
        # Known-bad router fixture: the dispatch worker updates a
        # replica-depth field under the router lock, but the routing
        # path reads it lock-free to score replicas. That torn read is
        # exactly the bug class ReplicaSet._score avoids by routing
        # under self._lock.
        mod = tmp_path / "router.py"
        mod.write_text(textwrap.dedent("""\
            import threading

            class Router:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.depth = 0
                    self._t = threading.Thread(target=self._drain,
                                               daemon=True)

                def _drain(self):
                    while True:
                        with self._lock:
                            self.depth -= 1

                def route(self):
                    return self.depth

                def enroll(self):
                    with self._lock:
                        self.depth += 1
        """))
        findings = analyze_paths([mod], entry_classes={"Router"})
        errs = _errors(findings)
        assert "field-race" in _rules(findings)
        assert any("Router.depth" in f.message for f in errs)

    def test_resilience_files_in_default_scope(self):
        from repro.analysis.static.concurrency_pass import (LOCK_ORDER,
                                                            SCOPE_DIRS)
        assert "src/repro/serving/chaos.py" in SCOPE_DIRS
        assert "src/repro/serving/resilience.py" in SCOPE_DIRS
        # The coordinator's handler runs from the pipeline's failure
        # path, so its lock nests inside the pipeline's; the injector
        # is polled inside the executor-cache miss path.
        assert (LOCK_ORDER.index("DispatchPipeline._lock")
                < LOCK_ORDER.index("ResilienceCoordinator._lock"))
        assert (LOCK_ORDER.index("ExecutorCache._lock")
                < LOCK_ORDER.index("ChaosInjector._lock"))
        for name in ("ResilienceCoordinator._lock", "DispatchWatchdog._lock",
                     "BrownoutController._lock", "ChaosInjector._lock"):
            for leaf in ("Counter._lock", "Histogram._lock"):
                assert LOCK_ORDER.index(name) < LOCK_ORDER.index(leaf)

    def test_unlocked_retry_counter_caught(self, tmp_path):
        # Known-bad resilience fixture: a retry loop bumps its attempt
        # counter lock-free while the public snapshot reads it under
        # the lock — the shape of bug ResilienceCoordinator avoids by
        # counting retries through the locked ServerStats hooks.
        mod = tmp_path / "res.py"
        mod.write_text(textwrap.dedent("""\
            import threading

            class Coordinator:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.retries = 0
                    self._t = threading.Thread(target=self._retry_loop,
                                               daemon=True)

                def _retry_loop(self):
                    while True:
                        self.retries += 1

                def snapshot(self):
                    with self._lock:
                        return {"retries": self.retries}
        """))
        findings = analyze_paths([mod], entry_classes={"Coordinator"})
        errs = _errors(findings)
        assert "field-race" in _rules(findings)
        assert any("Coordinator.retries" in f.message for f in errs)


# -------------------------------------------------------------- bench -----

class TestBenchCheck:
    def test_flatten(self):
        flat = flatten_metrics({"a": {"ms": 1.5, "ok": True, "note": "x"},
                                "n": 3})
        assert flat == {"a.ms": 1.5, "n": 3}

    def test_roundtrip_is_clean(self, tmp_path):
        path = tmp_path / "BENCH_test.json"
        write_bench_json(path, "bench_test", "bench_test --smoke",
                         "2026-08-08", {"cora": {"ms": 2.0}})
        assert check_bench_file(path) == []
        assert check_bench_files(tmp_path) == []

    @pytest.mark.parametrize("doc", [
        "not json {",
        json.dumps([1, 2]),
        # schema 1 (pre-provenance) files must fail until reseeded
        json.dumps({"bench": "b", "schema": 1, "created": "d",
                    "command": "c", "metrics": {"m": 1}}),
        # schema 2 without the provenance block
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c", "metrics": {"m": 1}}),
        # provenance present but not an object
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c", "provenance": "b93d566",
                    "metrics": {"m": 1}}),
        # provenance with a missing / empty / non-string key
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c",
                    "provenance": {"git_sha": "x", "jax_version": "y"},
                    "metrics": {"m": 1}}),
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c",
                    "provenance": {"git_sha": "", "jax_version": "y",
                                   "backend": "cpu"},
                    "metrics": {"m": 1}}),
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c",
                    "provenance": {"git_sha": 7, "jax_version": "y",
                                   "backend": "cpu"},
                    "metrics": {"m": 1}}),
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c",
                    "provenance": {"git_sha": "x", "jax_version": "y",
                                   "backend": "cpu"},
                    "metrics": {"m": "fast"}}),
        json.dumps({"bench": "b", "schema": 2, "created": "d",
                    "command": "c",
                    "provenance": {"git_sha": "x", "jax_version": "y",
                                   "backend": "cpu"},
                    "metrics": {"m": True}}),
        json.dumps({"schema": 2, "created": "d", "command": "c",
                    "provenance": {"git_sha": "x", "jax_version": "y",
                                   "backend": "cpu"},
                    "metrics": {"m": 1}}),
    ])
    def test_malformed_files_fail(self, tmp_path, doc):
        path = tmp_path / "BENCH_bad.json"
        path.write_text(doc)
        assert _errors(check_bench_file(path))

    def test_provenance_collected_automatically(self, tmp_path):
        path = tmp_path / "BENCH_test.json"
        doc = write_bench_json(path, "bench_test", "bench_test --smoke",
                               "2026-08-08", {"ms": 1.0})
        prov = doc["provenance"]
        assert set(prov) == {"git_sha", "jax_version", "backend"}
        assert all(isinstance(v, str) and v for v in prov.values())

    def test_required_metrics_enforced(self, tmp_path):
        # a bench_spmm trajectory missing one of the kernel-health
        # metrics regressed its reporting contract -> schema error
        path = tmp_path / "BENCH_spmm.json"
        write_bench_json(path, "bench_spmm", "bench_spmm --smoke",
                         "2026-08-08",
                         {"cora": {"launches_per_spmm": 1,
                                   "ell_pad_waste_x": 6.0}})
        (finding,) = _errors(check_bench_file(path))
        assert "achieved_roofline_frac" in finding.message
        write_bench_json(path, "bench_spmm", "bench_spmm --smoke",
                         "2026-08-08",
                         {"cora": {"launches_per_spmm": 1,
                                   "ell_pad_waste_x": 6.0,
                                   "achieved_roofline_frac": 0.004}})
        assert check_bench_file(path) == []

    def test_required_metrics_scoped_to_bench(self, tmp_path):
        # other benches carry no required set — the suffix match must
        # not leak bench_spmm's contract onto them
        path = tmp_path / "BENCH_other.json"
        write_bench_json(path, "bench_other", "bench_other", "2026-08-08",
                         {"ms": 1.0})
        assert check_bench_file(path) == []

    def test_committed_trajectories_valid(self, repo_root):
        findings = check_bench_files(repo_root)
        assert _errors(findings) == []


# ---------------------------------------------------------- repo gate -----

@pytest.fixture(scope="module")
def repo_root():
    from repro.analysis.static.concurrency_pass import _repo_root
    return _repo_root()


def test_whole_repo_lint_is_clean():
    """The exact gate scripts/lint_repro.py applies in tier-1 CI."""
    report = Report()
    report.extend(run_jaxpr_pass())
    report.extend(run_kernel_pass())
    report.extend(run_concurrency_pass())
    assert report.ok, "\n" + report.render(verbose=True)
    err, warn, _ = report.counts()
    assert (err, warn) == (0, 0), report.render(verbose=True)
