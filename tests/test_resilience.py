"""Failure-containment tests (ISSUE 10): arbitrary seeded chaos
schedules must never strand a future, resolve one twice, corrupt an
innocent request's output, or break per-key ordering. The property
test runs under the deterministic hypothesis stub offline, so every
example is a fixed, replayable schedule."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving import (BrownoutController, ChaosInjector, FaultPlan,
                           FaultSpec, InjectedFault, PoisonedRequest,
                           RequestQueue, RetryPolicy, SimClock, StubEngine,
                           bursty_trace, replay_trace)
from repro.serving.chaos import SITES
from repro.serving.simulate import (Arrival, _assert_key_order,
                                    _attach_order_probe)

NAMES = ["pqa0", "pqa1", "pqb0", "pqb1"]


def _world(plan, *, replicas=None, trace_seed=0, n_bursts=8, burst=6):
    """A StubEngine world on SimClock with resilience installed: replay
    a bursty trace under ``plan`` and drain. Returns everything the
    invariant checks need."""
    clock = SimClock()
    engine = StubEngine(clock, base_s=0.004, per_item_s=0.001,
                        stage_s=0.002, compile_s=0.25,
                        replicas=replicas or 1,
                        sclass_of=lambda name: name[:3])
    for nm in NAMES:
        engine.register(nm)
    xs = {nm: np.full((4, 3), float(i + 1), np.float32)
          for i, nm in enumerate(NAMES)}
    injector = ChaosInjector(plan)
    kw = {}
    if replicas:
        kw = {"replicas": replicas, "max_inflight": 4}
    queue = RequestQueue(engine, target_batch=4, default_deadline_ms=2000.0,
                        clock=clock, injector=injector, resilience=True, **kw)
    order = _attach_order_probe(queue)
    trace = bursty_trace(n_bursts, burst, 0.010, NAMES, seed=trace_seed)
    t0 = clock()
    trace = [Arrival(a.t_s + t0 + 0.05, a.name) for a in trace]
    futs, rej = replay_trace(queue, trace, xs.__getitem__)
    queue.drain()
    return queue, injector, trace, futs, rej, order, xs


def _check_invariants(injector, trace, futs, order, xs):
    """The universal containment contract, independent of schedule:
    exactly-once resolution, typed failures only, bitwise-equal
    successes, per-key order among non-quarantined requests."""
    admitted = [(a, f) for a, f in zip(trace, futs) if f is not None]
    assert all(f.done() for _, f in admitted), "stranded futures"
    # exactly once: the done-callback probe fires once per future
    assert len(order) == len(set(order)) == len(admitted), \
        "a future resolved zero or multiple times"
    poisoned = injector.poisoned_names()
    ok = []
    for arr, f in zip(trace, futs):
        if f is None:
            continue
        err = f.exception(timeout=0)
        if err is None:
            np.testing.assert_array_equal(f.result(timeout=0),
                                          xs[arr.name] * 2.0)
            ok.append((arr, f))
        elif isinstance(err, PoisonedRequest):
            assert arr.name in poisoned, \
                f"innocent request {arr.name!r} quarantined"
        else:
            # only an exhausted permanent fault may surface raw
            assert isinstance(err, InjectedFault) and not err.transient, \
                f"unexpected failure type: {err!r}"
    _assert_key_order([a for a, _ in ok], [f for _, f in ok], order)


class TestChaosProperty:
    @given(trace_seed=st.integers(0, 9999),
           replicas=st.integers(2, 3),
           faults=st.lists(st.tuples(st.sampled_from(SITES),
                                     st.integers(0, 24),
                                     st.booleans()),
                           min_size=0, max_size=6),
           member=st.integers(0, 7))
    @settings(max_examples=8, deadline=None)
    def test_property_containment_under_arbitrary_schedules(
            self, trace_seed, replicas, faults, member):
        specs, used, killed = [], set(), 0
        for site, at, perm in faults:
            if (site, at) in used:
                continue
            if site == "replica":
                if killed:        # at most one lane dies: >=1 healthy
                    continue
                killed += 1
            used.add((site, at))
            mode = "permanent" if (perm and site == "dispatch") \
                else "transient"
            specs.append(FaultSpec(site=site, at=at, mode=mode,
                                   member=member))
        _, injector, trace, futs, _, order, xs = _world(
            FaultPlan(tuple(specs)), replicas=replicas,
            trace_seed=trace_seed)
        _check_invariants(injector, trace, futs, order, xs)

    def test_seeded_plan_replays_identically(self):
        # Same seed -> same plan -> bitwise-identical outcome set.
        def run():
            plan = FaultPlan.seeded(seed=11, n_faults=5, horizon=30,
                                    sites=("dispatch", "compile", "hang",
                                           "poison"))
            _, inj, trace, futs, _, _, _ = _world(plan, replicas=2,
                                                  trace_seed=3)
            outs = []
            for a, f in zip(trace, futs):
                err = f.exception(timeout=0) if f is not None else None
                outs.append((a.name, type(err).__name__ if err else
                             float(np.asarray(f.result(timeout=0)).sum())))
            return inj.fired(), tuple(outs)
        assert run() == run()


class TestPermanentFault:
    def test_permanent_dispatch_fault_fails_only_its_batch(self):
        plan = FaultPlan((FaultSpec(site="dispatch", at=4,
                                    mode="permanent"),))
        _, injector, trace, futs, rej, order, xs = _world(plan, replicas=2)
        assert not any(rej)
        failed = [(a, f) for a, f in zip(trace, futs)
                  if f.exception(timeout=0) is not None]
        assert failed, "the permanent fault must surface to its members"
        for _, f in failed:
            err = f.exception(timeout=0)
            assert isinstance(err, InjectedFault) and not err.transient
        _check_invariants(injector, trace, futs, order, xs)


class TestSerialPath:
    def test_serial_retry_and_quarantine(self):
        # No pipeline, no replicas: _dispatch_group's inline containment.
        plan = FaultPlan((FaultSpec(site="dispatch", at=3),
                          FaultSpec(site="hang", at=6),
                          FaultSpec(site="poison", at=9, member=0)))
        queue, injector, trace, futs, rej, order, xs = _world(plan)
        assert not any(rej)
        _check_invariants(injector, trace, futs, order, xs)
        poisoned = injector.poisoned_names()
        assert len(poisoned) == 1
        n_failed = sum(1 for f in futs if f.exception(timeout=0) is not None)
        res = queue.stats.snapshot()["resilience"]
        assert res["retries"] >= 1, res
        assert res["quarantined"] == n_failed >= 1, res
        fired = {s for s, _ in injector.fired()}
        assert fired == {"dispatch", "hang", "poison"}


class TestUnits:
    def test_retry_policy_deterministic_and_bounded(self):
        p = RetryPolicy(max_attempts=3, backoff_base_s=1e-3, seed=42)
        a = [p.backoff_s(i, token=7) for i in (1, 2, 3)]
        b = [p.backoff_s(i, token=7) for i in (1, 2, 3)]
        assert a == b, "backoff must be a pure function of (seed,token,i)"
        assert a[0] < a[1] < a[2], "backoff must grow"
        assert p.backoff_s(1, token=8) != a[0], "token decorrelates jitter"

    def test_brownout_hysteresis(self):
        b = BrownoutController(high_depth=10, low_depth=4)
        assert not b.observe(9, now=0.0)
        assert b.observe(10, now=0.1), "high watermark trips"
        assert b.observe(5, now=0.2), "stays active above low watermark"
        assert not b.observe(4, now=0.3), "recovers at low watermark"
        assert not b.observe(9, now=0.4), "re-arms only at high"

    def test_null_injector_is_inert(self):
        from repro.serving import NULL_INJECTOR
        assert not NULL_INJECTOR.enabled
        assert not NULL_INJECTOR.is_poisoned("anything")

    def test_injector_replica_filter(self):
        plan = FaultPlan((FaultSpec(site="dispatch", at=0, replica=1),))
        inj = ChaosInjector(plan)
        assert inj.poll("dispatch", replica=0) is None  # wrong lane
        inj2 = ChaosInjector(plan)
        assert inj2.poll("dispatch", replica=1) is not None
