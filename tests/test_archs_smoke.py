"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config and runs one forward/train step on CPU; outputs must have
the right shapes and be finite."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED, all_cells, get_arch
from repro.data.graphs import random_edge_list, random_molecules
from repro.models import dimenet as dimenet_m
from repro.models import fm as fm_m
from repro.models import gnn as gnn_m
from repro.models import nequip as nequip_m
from repro.models import transformer as tfm
from repro.train import steps as S
from repro.train.optimizer import AdamW

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["mixtral-8x7b", "qwen3-moe-235b-a22b", "granite-8b",
            "qwen3-0.6b", "smollm-360m"]


def test_grid_is_complete():
    cells = all_cells()
    assert len(cells) == 40
    assert sum(1 for _, c in cells if c.skip) == 4      # long_500k skips
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke_train_step(arch_name):
    cfg = get_arch(arch_name).smoke
    params = tfm.init_params(cfg, KEY)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(S.make_lm_train_step(cfg, opt, remat=False,
                                        q_chunk=8, k_chunk=8, xent_chunk=8))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    params, opt_state, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    l2 = step(params, opt_state, batch)[2]["loss"]
    assert float(l2) < float(m["loss"])        # one step reduces the loss


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke_decode(arch_name):
    cfg = get_arch(arch_name).smoke
    params = tfm.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    _, cache = tfm.prefill(params, toks, cfg, max_len=20, q_chunk=4,
                           k_chunk=4)
    logits, cache = tfm.decode_step(params, cache, toks[:, :1], cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_name", ["gatedgcn", "meshgraphnet"])
def test_mpnn_smoke(arch_name):
    cfg = get_arch(arch_name).smoke
    s, r = random_edge_list(60, 240, seed=0)
    rng = np.random.default_rng(0)
    batch = {
        "senders": jnp.asarray(s), "receivers": jnp.asarray(r),
        "node_feat": jnp.asarray(rng.standard_normal((60, 12)), jnp.float32),
        "edge_feat": jnp.asarray(rng.standard_normal((len(s), 4)),
                                 jnp.float32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, 60), jnp.int32),
        "node_mask": jnp.ones((60,), bool),
    }
    if arch_name == "gatedgcn":
        params = gnn_m.gatedgcn_init(cfg, 12, 4, KEY)
    else:
        params = gnn_m.meshgraphnet_init(cfg, 12, 4, KEY)
    opt = AdamW(lr=1e-3)
    step = jax.jit(S.make_gnn_train_step(cfg, opt))
    p, o, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    serve = jax.jit(S.make_gnn_serve_step(cfg))
    out = serve(p, batch)
    assert out.shape == (60, cfg.n_classes)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch_name", ["dimenet", "nequip"])
def test_geometric_smoke(arch_name):
    cfg = get_arch(arch_name).smoke
    mols = random_molecules(4, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in mols.items() if k != "n_mols"}
    batch["energy"] = jnp.zeros((4,), jnp.float32)
    if arch_name == "dimenet":
        params = dimenet_m.dimenet_init(cfg, KEY)
    else:
        params = nequip_m.nequip_init(cfg, KEY)
        batch = {k: batch[k] for k in ("z", "pos", "edge_src", "edge_dst",
                                       "mol_id", "energy")}
    opt = AdamW(lr=1e-3)
    step = jax.jit(S.make_gnn_train_step(cfg, opt))
    p, o, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))


def test_gcn_paper_smoke():
    cfg = get_arch("gcn-paper").smoke
    s, r = random_edge_list(50, 200, seed=1)
    rng = np.random.default_rng(1)
    g = gnn_m.Graph(jnp.asarray(s), jnp.asarray(r),
                    jnp.asarray(rng.standard_normal((50, 16)), jnp.float32))
    params = gnn_m.gcn_init(cfg, 16, KEY)
    out = gnn_m.gcn_forward(params, g, cfg)
    assert out.shape == (50, cfg.n_classes)
    assert bool(jnp.isfinite(out).all())


def test_fm_smoke():
    cfg = get_arch("fm").smoke
    params = fm_m.fm_init(cfg, KEY)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(
        rng.integers(0, 10, (32, cfg.n_sparse)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, 32), jnp.float32)
    opt = AdamW(lr=1e-2)
    step = jax.jit(S.make_fm_train_step(cfg, opt))
    p, o, m = step(params, opt.init(params), {"idx": idx, "labels": labels})
    assert np.isfinite(float(m["loss"]))
    scores = jax.jit(S.make_fm_serve_step(cfg))(p, {"idx": idx})
    assert scores.shape == (32,)
    assert bool(jnp.isfinite(scores).all())


def test_fm_full_config_shapes():
    cfg = get_arch("fm").config
    assert len(cfg.vocab_sizes) == 39
    assert sum(cfg.vocab_sizes) > 30_000_000   # huge-table regime
