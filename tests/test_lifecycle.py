"""Shape-class lifecycle (PR 4): waste telemetry against hand-computed
padded-MAC counts, registry retirement/re-admission, executor
invalidation, the unpad round-trip, the LifecycleManager policy
(hysteresis, traffic gate, budgets) on the zero-compile stub, and the
real-engine retirement path (bitwise-stable outputs, no stranded
in-flight batches)."""
import numpy as np
import pytest

from repro.core import csr_from_dense
from repro.core.partition import PartitionConfig, analyze_and_partition
from repro.engine import (ClassRegistry, Engine, LifecycleConfig,
                          LifecycleManager, ShapePolicy, class_requirements,
                          grow_class, pad_to_class, unpad_from_class)
from repro.serving import (RequestQueue, SimClock, StubEngine,
                           StubShapeClass, run_lifecycle_smoke)

from conftest import make_heterogeneous_matrix


# ---------------------------------------------------------------------------
# class_waste vs hand-computed padded-MAC counts
# ---------------------------------------------------------------------------

class TestClassWasteHandComputed:
    def test_dense_only_graph_exact_counts(self):
        """A fully-dense 64x64 graph: every number derivable by hand.

        4096 nnz -> 1 dense tile. Founding applies growth 2.0 then the
        dense granule 4: capacity = 4 tiles * 64*64 = 16384 MAC slots.
        No ELL units, no COO nnz -> those capacities are 0, so
        padded_mac_waste_frac = 1 - 4096/16384 = 0.75 exactly.
        """
        a = np.abs(np.random.default_rng(0).standard_normal(
            (64, 64))).astype(np.float32)
        eng = Engine(partition_cfg=PartitionConfig(tile=64))
        eng.register("d", csr_from_dense(a))
        waste = eng.stats()["class_waste"]
        assert len(waste) == 1
        w = next(iter(waste.values()))
        assert w["members"] == 1
        assert w["dense_nnz"] == 4096
        assert w["dense_capacity"] == 4 * 64 * 64 == 16384
        assert w["ell_nnz"] == 0 and w["ell_capacity"] == 0
        assert w["coo_nnz"] == 0 and w["coo_capacity"] == 0
        assert w["ell_waste_frac"] == 0.0
        assert w["padded_mac_waste_frac"] == pytest.approx(0.75)

    def test_two_members_double_capacity(self):
        a = np.abs(np.random.default_rng(0).standard_normal(
            (64, 64))).astype(np.float32)
        eng = Engine(partition_cfg=PartitionConfig(tile=64))
        eng.register("d0", csr_from_dense(a))
        eng.register("d1", csr_from_dense(a))
        w = next(iter(eng.stats()["class_waste"].values()))
        assert w["members"] == 2
        assert w["dense_nnz"] == 2 * 4096
        assert w["dense_capacity"] == 2 * 16384
        assert w["padded_mac_waste_frac"] == pytest.approx(0.75)

    def test_formulas_match_documented_contract(self):
        """The telemetry contract (docs/TELEMETRY.md): per class,
        ell_capacity = sum(K*n over bands)*r_block*members (the banded
        kernel executes each capacity slot at its band's K width; an
        unbanded class has the single band (Kmax, units)), dense_capacity
        = n_dense_tiles*T^2*members, coo_capacity = coo_nnz*members, and
        the fracs follow from members' true meta nnz."""
        eng = Engine()
        metas = {}
        for i, n in enumerate([300, 304, 308]):
            a = make_heterogeneous_matrix(n, seed=i)
            h = eng.register(f"g{i}", csr_from_dense(a))
            metas[f"g{i}"] = (h.sclass, h.meta)
        for sc, entry in eng.class_waste_by_class().items():
            members = [(s, m) for s, m in metas.values() if s == sc]
            m = len(members)
            assert entry["members"] == m
            band_macs = sum(k * n for k, n in sc.bands)
            assert sum(n for _, n in sc.bands) == sc.ell_units
            assert entry["ell_capacity"] == band_macs * sc.r_block * m
            assert entry["ell_capacity"] <= \
                sc.ell_kmax * sc.ell_units * sc.r_block * m
            assert entry["dense_capacity"] == \
                sc.n_dense_tiles * sc.tile * sc.tile * m
            assert entry["coo_capacity"] == sc.coo_nnz * m
            ell_nnz = sum(meta.nnz_ell for _, meta in members)
            assert entry["ell_nnz"] == ell_nnz
            true = ell_nnz + sum(meta.nnz_dense + meta.nnz_coo
                                 for _, meta in members)
            cap = (entry["ell_capacity"] + entry["dense_capacity"]
                   + entry["coo_capacity"])
            assert entry["padded_mac_waste_frac"] == \
                pytest.approx(1.0 - true / cap)
            assert entry["ell_waste_frac"] == \
                pytest.approx(1.0 - ell_nnz / entry["ell_capacity"])


# ---------------------------------------------------------------------------
# registry retirement / re-admission / planning
# ---------------------------------------------------------------------------

def _need_of(n, seed=0):
    a = make_heterogeneous_matrix(n, seed=seed)
    part, meta, _ = analyze_and_partition(csr_from_dense(a),
                                          PartitionConfig(tile=64))
    return class_requirements(part, meta)


class TestRegistryLifecycle:
    def test_retire_blocks_new_members_and_counts(self):
        reg = ClassRegistry(ShapePolicy())
        need = _need_of(300)
        sc = reg.classify_need(need)
        assert reg.retire(sc)
        assert sc not in reg.classes and sc in reg.retired
        assert not reg.retire(sc), "double-retire must be a no-op"
        sc2 = reg.classify_need(need)
        assert sc2 != sc or sc2 not in reg.retired
        st = reg.stats()
        assert st["retires"] == 1 and st["live_classes"] == 1

    def test_refound_is_counted_and_revives(self):
        reg = ClassRegistry(ShapePolicy())
        need = _need_of(300)
        sc = reg.classify_need(need)
        reg.retire(sc)
        sc2 = reg.classify_need(need)   # same need -> identical class
        assert sc2 == sc
        assert reg.refounds == 1
        assert sc2 in reg.classes and sc2 not in reg.retired

    def test_plan_reclass_is_pure_and_tight(self):
        reg = ClassRegistry(ShapePolicy())
        need = _need_of(300)
        sc = reg.classify_need(need)
        before = list(reg.classes)
        targets, new = reg.plan_reclass([need], exclude=(sc,))
        assert reg.classes == before, "planning must not mutate"
        assert len(targets) == 1 and len(new) == 1
        tight = grow_class(need, ShapePolicy(growth=1.0, coo_growth=1.0))
        assert targets[0] == tight == new[0]
        # with nothing excluded the need first-fits its own class
        targets2, new2 = reg.plan_reclass([need])
        assert targets2 == [sc] and new2 == []

    def test_admit_readmits(self):
        reg = ClassRegistry(ShapePolicy())
        sc = reg.classify_need(_need_of(300))
        reg.retire(sc)
        reg.admit(sc)
        assert sc in reg.classes and sc not in reg.retired
        assert reg.refounds == 1


# ---------------------------------------------------------------------------
# executor invalidation + unpad round-trip
# ---------------------------------------------------------------------------

class TestInvalidationAndUnpad:
    def test_invalidate_class_drops_only_that_class(self):
        eng = Engine()
        b = {}
        for i, n in enumerate([300, 90]):   # far apart -> distinct classes
            a = make_heterogeneous_matrix(n, seed=i)
            eng.register(f"g{i}", csr_from_dense(a))
            b[f"g{i}"] = np.random.default_rng(i).standard_normal(
                (n, 8)).astype(np.float32)
        eng.spmm("g0", b["g0"])
        eng.spmm("g1", b["g1"])
        sc0, sc1 = eng.handle("g0").sclass, eng.handle("g1").sclass
        assert sc0 != sc1 and eng.executors.size == 2
        n_dropped = eng.executors.invalidate_class(sc0)
        assert n_dropped == 1 and eng.executors.size == 1
        assert eng.executors.stats.invalidations == 1
        assert eng.executors.stats.evictions == 0, \
            "invalidation must not masquerade as LRU eviction"
        # g1's executor survives: next call is a pure hit
        hits = eng.executors.stats.hits
        eng.spmm("g1", b["g1"])
        assert eng.executors.stats.hits == hits + 1

    def test_unpad_round_trips_bitwise(self):
        a = make_heterogeneous_matrix(300, seed=0)
        part, meta, _ = analyze_and_partition(csr_from_dense(a),
                                              PartitionConfig(tile=64))
        sc = grow_class(class_requirements(part, meta))
        padded, pmeta = pad_to_class(part, meta, sc)
        rec = unpad_from_class(padded, pmeta, meta)
        for name in ("dense", "ell", "coo"):
            orig, got = getattr(part, name), getattr(rec, name)
            for field in orig._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(orig, field)),
                    np.asarray(getattr(got, field)),
                    err_msg=f"{name}.{field} did not round-trip")
        # and the recovered partition re-pads into a DIFFERENT class
        tight = grow_class(class_requirements(part, meta),
                           ShapePolicy(growth=1.0, coo_growth=1.0))
        repadded, _ = pad_to_class(rec, meta, tight)
        assert repadded.ell.cols.shape[0] == tight.ell_units


# ---------------------------------------------------------------------------
# LifecycleManager policy on the zero-compile stub
# ---------------------------------------------------------------------------

def _stub_world(**cfg_kw):
    clock = SimClock()
    engine = StubEngine(clock)
    queue = RequestQueue(engine, target_batch=4, default_deadline_ms=500.0,
                         clock=clock)
    cfg_kw.setdefault("waste_budget", 0.52)
    cfg_kw.setdefault("breach_windows", 2)
    cfg_kw.setdefault("min_traffic", 1)
    mgr = LifecycleManager(engine, frontend=queue,
                           config=LifecycleConfig(**cfg_kw))
    x = np.full((4, 3), 1.0, np.float32)

    def serve(names):
        futs = [queue.submit(n, x) for n in names]
        queue.drain()
        return futs

    return clock, engine, queue, mgr, x, serve


class TestLifecyclePolicy:
    def test_hysteresis_needs_consecutive_breaches(self):
        clock, engine, queue, mgr, x, serve = _stub_world()
        for i in range(3):
            engine.register(f"b{i}", size=100)
        for i in range(4):
            engine.register(f"s{i}", size=60)   # waste 0.61 > budget
        serve([f"b{i}" for i in range(3)])
        assert mgr.step()["retired"] == [], "first breach must not retire"
        # a window back under budget resets the streak entirely
        mgr._tracks[engine.classes[0]].ewma_waste = 0.1
        serve([f"b{i}" for i in range(3)])
        assert mgr.step()["retired"] == []
        assert mgr._tracks[engine.classes[0]].breaches <= 1, \
            "dipping under budget must reset the breach streak"

    def test_traffic_gate_spares_idle_classes(self):
        clock, engine, queue, mgr, x, serve = _stub_world()
        for i in range(3):
            engine.register(f"b{i}", size=100)
        for i in range(4):
            engine.register(f"s{i}", size=60)
        # never served: waste is high but the class runs no kernels
        for _ in range(4):
            w = mgr.step()
        assert w["retired"] == [] and mgr.retires == 0

    def test_recompile_budget_skips_not_truncates(self):
        clock, engine, queue, mgr, x, serve = _stub_world(
            max_recompiles_per_window=0)
        for i in range(3):
            engine.register(f"b{i}", size=100)
        for i in range(4):
            engine.register(f"s{i}", size=60)
        names = [f"b{i}" for i in range(3)] + [f"s{i}" for i in range(4)]
        serve(names)
        mgr.step()
        serve(names)
        w = mgr.step()
        assert w["retired"] == [], "plan exceeding recompile budget skips"
        assert w["skipped"].get("recompile_budget", 0) == 1
        assert len(engine.classes) == 1, "no partial retirement"

    def test_no_tighter_plan_backs_off_instead_of_churning(self):
        # A dense-only 64x64 graph: granule floors make the tight
        # re-found IDENTICAL to its class (4-tile dense granule both
        # ways), so retiring would invalidate + recompile the same
        # executors forever. The policy must skip with "no_tighter"
        # and cool the class down, not churn.
        a = np.abs(np.random.default_rng(0).standard_normal(
            (64, 64))).astype(np.float32)
        eng = Engine(partition_cfg=PartitionConfig(tile=64))
        eng.register("d", csr_from_dense(a))
        eng.spmm("d", np.ones((64, 4), np.float32))
        mgr = LifecycleManager(eng, config=LifecycleConfig(
            waste_budget=0.05, breach_windows=1, min_traffic=0,
            cooldown_windows=2))
        w = mgr.step()
        assert w["retired"] == []
        assert w["skipped"].get("no_tighter") == 1
        assert eng.registry.stats()["retires"] == 0
        assert eng.executors.stats.invalidations == 0
        # cooldown: the next window doesn't even re-plan
        w2 = mgr.step()
        assert w2["skipped"] == {}

    def test_stale_plan_regroups_by_current_key(self):
        # A plan popped out of the scheduler (worker mid-pump) is
        # invisible to drain_class; if a retirement re-classes its
        # members before dispatch, the dispatch must re-derive keys
        # and split — never raise mixed-key or strand a future.
        clock, engine, queue, mgr, x, serve = _stub_world()
        engine.register("g0", size=100)
        engine.register("g1", size=100)
        f0, f1 = queue.submit("g0", x), queue.submit("g1", x)
        plans = queue.scheduler.close_matching(lambda k: True)
        assert len(plans) == 1 and len(plans[0].members) == 2
        # retirement-like mutation lands between poll and dispatch
        engine.handle("g1").sclass = StubShapeClass(cap=100, gen=99)
        queue._dispatch(plans[0])
        assert f0.done() and f1.done()
        assert queue.stats.dispatch_errors == 0
        np.testing.assert_array_equal(f1.result(timeout=0), x * 2.0)
        assert queue.stats.batches == 2, \
            "split members must dispatch as two same-key batches"

    def test_smoke_end_to_end(self):
        snap = run_lifecycle_smoke(verbose=False)
        assert snap["retires"] == 1
        assert snap["recompiles"] <= 2


class TestTrafficWeightedWaste:
    """ISSUE 5 satellite: the budget comparison weights each class's
    waste EWMA by its dispatch share, on hand-computed shares."""

    def _two_class_world(self, **cfg_kw):
        clock, engine, queue, mgr, x, serve = _stub_world(**cfg_kw)
        for i in range(3):
            engine.register(f"b{i}", size=100)   # founds cap=200: waste 0.5
        engine.register("tiny", size=10)         # founds cap=20:  waste 0.5
        assert len(engine.classes) == 2
        sc_a, sc_b = engine.classes
        # hand-built dispatch mix: 3 batches on A, 1 on B -> shares 3/4, 1/4
        for _ in range(3):
            serve([f"b{i}" for i in range(3)])
        serve(["tiny"])
        return engine, mgr, (sc_a, sc_b)

    def test_hand_computed_shares_scale_the_budget_comparison(self):
        engine, mgr, (sc_a, sc_b) = self._two_class_world(
            waste_budget=0.6, breach_windows=8)   # no retire, just track
        w = mgr.step()
        # raw EWMA waste is 0.5 for BOTH classes (nnz is half capacity);
        # relative dispatch shares: A ran 3 batches (the hottest ->
        # factor 3/3 = 1), B ran 1 (factor 1/3). Weighted:
        # A = 0.5 * 1 = 0.5, B = 0.5 * 1/3 = 0.1667
        assert mgr._tracks[sc_a].ewma_waste == pytest.approx(0.5)
        assert mgr._tracks[sc_b].ewma_waste == pytest.approx(0.5)
        assert mgr._tracks[sc_a].weighted_waste == pytest.approx(0.5)
        assert mgr._tracks[sc_b].weighted_waste == pytest.approx(0.5 / 3)
        # both sit under the 0.6 budget -> no breach
        assert w["retired"] == [] and w["breaching"] == 0

    def test_hot_class_breaches_cold_class_spared(self):
        engine, mgr, (sc_a, sc_b) = self._two_class_world(
            waste_budget=0.4, breach_windows=8)   # no retire, just track
        mgr.step()
        # 0.5 > 0.4 -> the hot class breaches; the cold one's identical
        # raw waste is discounted to 0.1667 < 0.4 and spared
        assert mgr._tracks[sc_a].breaches == 1
        assert mgr._tracks[sc_b].breaches == 0

    def test_weighting_off_restores_raw_comparison(self):
        engine, mgr, (sc_a, sc_b) = self._two_class_world(
            waste_budget=0.4, breach_windows=8, traffic_weight=False)
        mgr.step()
        assert mgr._tracks[sc_a].weighted_waste == pytest.approx(0.5)
        assert mgr._tracks[sc_a].breaches == 1
        assert mgr._tracks[sc_b].breaches == 1


class TestDeferredRetirement:
    """ISSUE 5 satellite: the drain barrier waits for a queue lull
    (no pending member inside its deadline-close horizon), with a
    max-defer fallback so traffic can't starve drift response."""

    def _breaching_world(self, **cfg_kw):
        cfg_kw.setdefault("waste_budget", 0.4)
        cfg_kw.setdefault("breach_windows", 1)
        clock, engine, queue, mgr, x, serve = _stub_world(**cfg_kw)
        for i in range(3):
            engine.register(f"b{i}", size=100)   # waste 0.5 > 0.4
        return clock, engine, queue, mgr, x, serve

    def test_defers_while_urgent_then_retires_at_lull(self):
        clock, engine, queue, mgr, x, serve = self._breaching_world()
        serve([f"b{i}" for i in range(3)])
        # a pending member with slack below safety*estimate: NOT a lull
        tight = queue.submit("b0", x, deadline_ms=0.01)
        w1 = mgr.step()
        assert w1["retired"] == []
        assert w1["skipped"].get("deferred") == 1
        assert not tight.done(), "deferral must not flush the request"
        queue.drain()        # the urgent request rides its natural close
        assert tight.done()
        serve([f"b{i}" for i in range(3)])   # keep the traffic gate open
        w2 = mgr.step()      # queue idle now -> lull -> retire proceeds
        assert len(w2["retired"]) == 1
        assert mgr.skipped.get("deferred") == 1

    def test_max_defer_windows_forces_retirement(self):
        clock, engine, queue, mgr, x, serve = self._breaching_world(
            max_defer_windows=2)
        tights = []
        for w in range(2):
            serve([f"b{i}" for i in range(3)])
            tights.append(queue.submit("b0", x, deadline_ms=0.01))
            report = mgr.step()
            assert report["retired"] == []
            assert report["skipped"].get("deferred") == 1
            queue.drain()
        serve([f"b{i}" for i in range(3)])
        tights.append(queue.submit("b0", x, deadline_ms=0.01))
        report = mgr.step()   # defer budget exhausted: retire anyway
        assert len(report["retired"]) == 1
        assert all(t.done() for t in tights), \
            "forced retirement must flush, not strand, urgent requests"
        assert queue.stats.close_reasons.get("retire", 0) >= 1

    def test_defer_disabled_retires_immediately(self):
        clock, engine, queue, mgr, x, serve = self._breaching_world(
            max_defer_windows=0)
        serve([f"b{i}" for i in range(3)])
        queue.submit("b0", x, deadline_ms=0.01)
        report = mgr.step()
        assert len(report["retired"]) == 1
        assert report["skipped"] == {}


# ---------------------------------------------------------------------------
# real-engine retirement: the full drain -> swap -> recompile path
# ---------------------------------------------------------------------------

class TestRealEngineRetirement:
    def _world(self):
        eng = Engine()
        rng = np.random.default_rng(0)
        xs = {}
        ws = [(rng.standard_normal((16, 8)) * 0.1).astype(np.float32),
              (rng.standard_normal((8, 4)) * 0.1).astype(np.float32)]
        for i, n in enumerate([300, 304, 308]):
            a = make_heterogeneous_matrix(n, seed=i)
            eng.register(f"g{i}", csr_from_dense(a), weights=ws)
            xs[f"g{i}"] = rng.standard_normal((n, 16)).astype(np.float32)
        return eng, xs

    def test_retirement_is_bitwise_invisible(self):
        eng, xs = self._world()
        pre = {k: np.asarray(eng.infer(k, x)) for k, x in xs.items()}
        sc = eng.handle("g0").sclass
        plan = eng.plan_retirement(sc)
        assert set(plan.names) == set(xs)
        res = eng.execute_retirement(plan)
        assert res["members"] == 3
        assert res["executors_invalidated"] >= 1
        assert eng.handle("g0").sclass != sc
        assert eng.registry.stats()["retires"] == 1
        for k, x in xs.items():
            np.testing.assert_array_equal(
                np.asarray(eng.infer(k, x)), pre[k],
                err_msg="retirement must be value-neutral")
        # successor class is tighter: strictly less ELL capacity
        assert eng.handle("g0").sclass.ell_mac_capacity < sc.ell_mac_capacity

    def test_retirement_drains_in_flight_batch(self):
        eng, xs = self._world()
        clock = SimClock()
        queue = RequestQueue(eng, target_batch=8, clock=clock,
                             default_deadline_ms=60_000.0)
        mgr = LifecycleManager(
            eng, frontend=queue,
            config=LifecycleConfig(waste_budget=0.05, breach_windows=1,
                                   min_traffic=0))
        futs = [queue.submit(k, x) for k, x in xs.items()]
        assert queue.depth() == 3, "batch must still be lingering"
        w = mgr.step()
        assert len(w["retired"]) == 1
        assert queue.depth() == 0
        assert all(f.done() for f in futs), \
            "retirement stranded an in-flight batch"
        assert queue.stats.close_reasons.get("retire") == 1
        for (k, x), f in zip(xs.items(), futs):
            np.testing.assert_array_equal(np.asarray(f.result(timeout=0)),
                                          np.asarray(eng.infer(k, x)))
        assert eng.stats()["lifecycle"]["retires"] == 1

    def test_stats_lifecycle_block_surfaces(self):
        eng, xs = self._world()
        mgr = LifecycleManager(eng)
        assert eng.stats()["lifecycle"]["windows"] == 0
        mgr.step()
        snap = eng.stats()["lifecycle"]
        assert snap["windows"] == 1
        assert snap["registry"]["live_classes"] >= 1
        assert "last_window" in snap
