"""Deterministic fallback for `hypothesis` in offline environments.

The tier-1 suite must collect and run without hypothesis installed
(ISSUE 1). This module mimics the tiny slice of the hypothesis API the
tests use — ``given``, ``settings``, and the ``integers`` / ``floats`` /
``lists`` / ``sampled_from`` / ``booleans`` / ``tuples`` strategies — by
replaying a fixed number of seeded pseudo-random examples per test.
Examples are derived from the test's qualified name, so runs are fully
deterministic and independent of execution order.

Installed by ``conftest.py`` via ``sys.modules["hypothesis"]`` only when
the real package is absent; with hypothesis installed the stub is inert.
"""
from __future__ import annotations

import inspect
import os
import zlib

import numpy as np

# Cap on examples per property test. Real hypothesis shrinks + caches;
# the stub just replays, so large max_examples (100) would dominate suite
# wall-clock for no added determinism.
_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_STUB_MAX_EXAMPLES", "10"))


class SearchStrategy:
    """A strategy is a draw function over a numpy Generator."""

    def __init__(self, draw, label=""):
        self._draw = draw
        self.label = label

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return f"stub-strategy({self.label})"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value},{max_value})")


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value},{max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)), "booleans")


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[int(rng.integers(len(seq)))],
                          f"sampled_from(n={len(seq)})")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]
    return SearchStrategy(draw, f"lists({elements.label})")


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies),
                          "tuples")


def settings(max_examples: int = 10, deadline=None, **_kw):
    """Records max_examples on the test; all other knobs are no-ops."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    """Replay ``max_examples`` seeded draws through the wrapped test.

    Like real hypothesis, positional strategies bind to the RIGHTMOST
    parameters (by name, so tests that also take pytest fixtures keep
    working), and the wrapper advertises a signature without the
    strategy-bound parameters so pytest does not mistake them for
    fixtures.
    """
    def deco(fn):
        n_examples = min(getattr(fn, "_stub_max_examples", 10),
                         _MAX_EXAMPLES_CAP)
        base_seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}"
                               .encode()) & 0xFFFFFFFF
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_bound = len(strategies)
        free = [p.name for p in params if p.name not in kw_strategies]
        pos_names = free[len(free) - n_bound:]

        def wrapper(*args, **kwargs):
            for i in range(n_examples):
                rng = np.random.default_rng((base_seed, i))
                drawn = {name: s.draw(rng)
                         for name, s in zip(pos_names, strategies)}
                drawn.update({k: s.draw(rng)
                              for k, s in kw_strategies.items()})
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"stub-hypothesis example #{i} failed for "
                        f"{fn.__qualname__} with {drawn}: {e}") from e

        bound = set(pos_names) | set(kw_strategies)
        kept = [p for p in params if p.name not in bound]
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)


strategies = _StrategiesModule()
