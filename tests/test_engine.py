"""Shape-class serving engine: padding exactness, cache behavior, fused
ELL dispatch, batching, and the ISSUE-1 partition edge cases (each
checked through BOTH the eager hybrid_spmm path and the cached engine)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (PartitionConfig, analyze_and_partition,
                        csr_from_dense, hybrid_spmm, hybrid_spmm_ref,
                        partition_to_dense)
from repro.engine import (ClassRegistry, Engine, ShapePolicy, class_fits,
                          class_requirements, grow_class, pad_to_class,
                          round_up_ladder, round_up_pow2, shape_class_of)

from conftest import (OVERFLOW_CFG, make_heterogeneous_matrix,
                      make_overflow_matrix)

TOL = dict(rtol=2e-5, atol=2e-4)


# ----------------------------------------------------- edge-case graphs ----
EDGE_CASES = {
    "empty": lambda: np.zeros((100, 100), np.float32),
    "single_tile": lambda: np.pad(
        (np.random.default_rng(1).random((64, 64)) < 0.08).astype(np.float32),
        ((0, 64), (0, 64))),
    "all_dense": lambda: np.abs(
        np.random.default_rng(2).standard_normal((64, 64))
    ).astype(np.float32),
    "ell_overflow": make_overflow_matrix,
}

EDGE_CFGS = {
    "ell_overflow": PartitionConfig(**OVERFLOW_CFG),
}


def _edge(name):
    a = EDGE_CASES[name]()
    cfg = EDGE_CFGS.get(name, PartitionConfig(tile=64))
    return a, cfg


class TestEdgeCasesEager:
    @pytest.mark.parametrize("name", sorted(EDGE_CASES))
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_hybrid_matches_ref(self, name, backend):
        a, cfg = _edge(name)
        part, meta, _ = analyze_and_partition(csr_from_dense(a), cfg)
        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal((a.shape[1], 16)), jnp.float32)
        y = np.asarray(hybrid_spmm(part, b, meta=meta, backend=backend))
        np.testing.assert_allclose(y, np.asarray(hybrid_spmm_ref(
            jnp.asarray(a), b)), **TOL)

    def test_overflow_routes_to_coo(self):
        a, cfg = _edge("ell_overflow")
        _, meta, _ = analyze_and_partition(csr_from_dense(a), cfg)
        assert meta.nnz_ell > 0, "capped rows must keep an ELL slice"
        assert meta.nnz_coo >= 4 * 64, "overflow nnz must spill to COO"

    def test_empty_and_dense_routing(self):
        _, meta, _ = analyze_and_partition(
            csr_from_dense(EDGE_CASES["empty"]()), PartitionConfig(tile=64))
        assert meta.nnz == 0
        _, meta, _ = analyze_and_partition(
            csr_from_dense(EDGE_CASES["all_dense"]()),
            PartitionConfig(tile=64))
        assert meta.nnz_dense == meta.nnz > 0


class TestEdgeCasesEngine:
    @pytest.mark.parametrize("name", sorted(EDGE_CASES))
    def test_engine_matches_ref(self, name):
        a, cfg = _edge(name)
        eng = Engine(partition_cfg=cfg)
        eng.register(name, csr_from_dense(a))
        rng = np.random.default_rng(0)
        b = rng.standard_normal((a.shape[1], 16)).astype(np.float32)
        y = np.asarray(eng.spmm(name, b))
        np.testing.assert_allclose(y, a @ b, **TOL)
        # second call reuses the cached executor
        y2 = np.asarray(eng.spmm(name, b))
        assert eng.executors.stats.hits >= 1
        np.testing.assert_allclose(y2, y, rtol=0, atol=0)


# ------------------------------------------------------- fused dispatch ----
class TestFusedDispatch:
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_fused_equals_loop(self, hetero300, backend):
        part, meta, _ = analyze_and_partition(csr_from_dense(hetero300),
                                              PartitionConfig(tile=64))
        assert len(meta.ell_segments) > 1, "need multiple K widths to fuse"
        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal((300, 32)), jnp.float32)
        y_fused = np.asarray(hybrid_spmm(part, b, meta=meta, backend=backend,
                                         ell_dispatch="fused"))
        y_loop = np.asarray(hybrid_spmm(part, b, meta=meta, backend=backend,
                                        ell_dispatch="loop"))
        np.testing.assert_allclose(y_fused, y_loop, **TOL)
        np.testing.assert_allclose(y_fused, hetero300 @ np.asarray(b), **TOL)

    def test_unknown_dispatch_raises(self, hetero300):
        part, meta, _ = analyze_and_partition(csr_from_dense(hetero300),
                                              PartitionConfig(tile=64))
        with pytest.raises(ValueError):
            hybrid_spmm(part, jnp.ones((300, 4)), meta=meta,
                        ell_dispatch="bogus")


# ------------------------------------------------- shape-class geometry ----
class TestShapeClass:
    def test_rounding_helpers(self):
        assert round_up_pow2(0, 4) == 0
        assert round_up_pow2(1, 4) == 4
        assert round_up_pow2(37, 4) == 64
        assert round_up_ladder(0, (1, 2, 4)) == 0
        assert round_up_ladder(3, (1, 2, 4)) == 4
        assert round_up_ladder(9, (1, 2, 4)) == 12   # multiples past the top

    def test_pad_to_class_is_exact(self, hetero300):
        part, meta, _ = analyze_and_partition(csr_from_dense(hetero300),
                                              PartitionConfig(tile=64))
        sc = shape_class_of(part, meta)
        padded, pmeta = pad_to_class(part, meta, sc)
        rec = partition_to_dense(padded, pmeta)
        assert rec.shape == (sc.n_row_tiles * 64, sc.n_col_tiles * 64)
        np.testing.assert_allclose(rec[:300, :300], hetero300, rtol=0, atol=0)
        assert np.count_nonzero(rec[300:, :]) == 0
        assert np.count_nonzero(rec[:, 300:]) == 0

    def test_registry_reuses_class_for_family(self):
        reg = ClassRegistry(ShapePolicy())
        classes = set()
        for i, n in enumerate([300, 310, 305, 296]):
            a = make_heterogeneous_matrix(n, seed=i)
            part, meta, _ = analyze_and_partition(csr_from_dense(a),
                                                  PartitionConfig(tile=64))
            classes.add(reg.classify(part, meta))
        assert len(classes) < 4, "similar graphs must share shape classes"
        assert len(reg.classes) == len(classes)

    def test_fit_rejects_oversized_class(self):
        a = make_heterogeneous_matrix(300, seed=0)
        part, meta, _ = analyze_and_partition(csr_from_dense(a),
                                              PartitionConfig(tile=64))
        need = class_requirements(part, meta)
        sc = grow_class(need)
        assert class_fits(need, sc)
        tiny = np.zeros((100, 100), np.float32)
        tiny[0, 1] = 1.0
        tpart, tmeta, _ = analyze_and_partition(csr_from_dense(tiny),
                                                PartitionConfig(tile=64))
        tneed = class_requirements(tpart, tmeta)
        assert not class_fits(tneed, sc), \
            "a tiny graph must not pad into a huge class"


# --------------------------------------------------------- serve_batch -----
class TestServing:
    def _engine_with_family(self, n_graphs=3, f_in=24, hidden=12, classes=5):
        eng = Engine()
        rng = np.random.default_rng(0)
        graphs = {}
        for i in range(n_graphs):
            n = 300 + 4 * i
            a = make_heterogeneous_matrix(n, seed=i)
            ws = [(rng.standard_normal((f_in, hidden)) * 0.1
                   ).astype(np.float32),
                  (rng.standard_normal((hidden, classes)) * 0.1
                   ).astype(np.float32)]
            eng.register(f"g{i}", csr_from_dense(a), weights=ws)
            graphs[f"g{i}"] = (a, ws, n)
        return eng, graphs, rng

    def _oracle(self, a, ws, x):
        h = np.maximum(a @ (x @ ws[0]), 0)
        return a @ (h @ ws[1])

    def test_infer_matches_oracle(self):
        eng, graphs, rng = self._engine_with_family(1)
        a, ws, n = graphs["g0"]
        x = rng.standard_normal((n, 24)).astype(np.float32)
        y = np.asarray(eng.infer("g0", x))
        np.testing.assert_allclose(y, self._oracle(a, ws, x),
                                   rtol=1e-4, atol=1e-3)

    def test_serve_batch_matches_individual(self):
        eng, graphs, rng = self._engine_with_family(3)
        reqs = []
        for i in [0, 1, 2, 1, 0]:   # odd batch -> exercises pow2 padding
            _, _, n = graphs[f"g{i}"]
            reqs.append((f"g{i}",
                         rng.standard_normal((n, 24)).astype(np.float32)))
        got = eng.serve_batch(reqs)
        assert len(got) == len(reqs)
        for (name, x), y in zip(reqs, got):
            a, ws, n = graphs[name]
            assert y.shape == (n, 5)
            np.testing.assert_allclose(np.asarray(y), self._oracle(a, ws, x),
                                       rtol=1e-4, atol=1e-3)

    def test_serve_batch_without_weights_raises(self):
        eng = Engine()
        eng.register("g", csr_from_dense(make_heterogeneous_matrix(64)))
        with pytest.raises(ValueError):
            eng.serve_batch([("g", np.ones((64, 4), np.float32))])

    def test_reorder_round_trip(self):
        a = make_heterogeneous_matrix(200, seed=3)
        sym = np.abs(a) + np.abs(a).T
        rng = np.random.default_rng(1)
        ws = [(rng.standard_normal((16, 8)) * 0.1).astype(np.float32),
              (rng.standard_normal((8, 3)) * 0.1).astype(np.float32)]
        eng = Engine()
        eng.register("r", csr_from_dense(sym), reorder="degree", weights=ws)
        x = rng.standard_normal((200, 16)).astype(np.float32)
        y = np.asarray(eng.infer("r", x))
        np.testing.assert_allclose(y, self._oracle(sym, ws, x),
                                   rtol=1e-3, atol=1e-2)
