"""Deeper LM model tests: decode==forward, SWA ring buffer, flash
attention vs naive oracle, MoE dispatch properties, chunked xent."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.models.attention import chunked_attention
from repro.train.steps import chunked_cross_entropy

KEY = jax.random.PRNGKey(0)


def _decode_matches_forward(cfg, s=24):
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = tfm.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    h = tfm.forward(params, toks, cfg, remat=False, q_chunk=8, k_chunk=8,
                    compute_dtype=None)
    logits_full = tfm.logits_fn(params, h, cfg)
    _, cache = tfm.prefill(params, toks[:, : s - 1], cfg, max_len=s + 4,
                           q_chunk=8, k_chunk=8, cache_dtype=jnp.float32,
                           compute_dtype=None)
    lg, _ = tfm.decode_step(params, cache, toks[:, s - 1: s], cfg,
                            compute_dtype=None)
    return float(jnp.abs(lg[:, 0] - logits_full[:, s - 1]).max())


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen3-moe-235b-a22b",
                                  "granite-8b", "qwen3-0.6b", "smollm-360m"])
def test_decode_matches_forward(arch):
    err = _decode_matches_forward(get_arch(arch).smoke)
    assert err < 5e-5, err


def test_swa_ring_buffer_long_decode():
    """Decode far past the window: ring buffer must match a full-cache
    reference at every step."""
    cfg = get_arch("mixtral-8x7b").smoke          # window 16
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    full = dataclasses.replace(cfg, sliding_window=None)
    params = tfm.init_params(cfg, KEY)
    n_steps, b = 40, 2
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, n_steps), 0,
                              cfg.vocab)

    cache_ring = tfm.init_cache(cfg, b, max_len=n_steps, dtype=jnp.float32)
    assert cache_ring["k"].shape[2] == cfg.sliding_window  # ring is small
    cache_full = tfm.init_cache(full, b, max_len=n_steps, dtype=jnp.float32)

    for t in range(n_steps):
        lr, cache_ring = tfm.decode_step(params, cache_ring, toks[:, t:t+1],
                                         cfg, compute_dtype=None)
        # full cache but windowed masking == ground truth sliding window
        lf, cache_full = tfm.decode_step(params, cache_full, toks[:, t:t+1],
                                         cfg if False else
                                         dataclasses.replace(
                                             full,
                                             sliding_window=cfg.sliding_window),
                                         compute_dtype=None)
        err = float(jnp.abs(lr - lf).max())
        assert err < 1e-4, (t, err)


def test_flash_attention_vs_naive_random_lengths():
    rng = np.random.default_rng(0)
    for trial in range(3):
        b, s, kv, g, d = 2, int(rng.integers(5, 40)), 2, 3, 8
        h = kv * g
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        pos = jnp.arange(s)
        out = chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                                q_chunk=7, k_chunk=5)
        qq = q.reshape(b, s, kv, g, d)
        sc = jnp.einsum("bqkgd,btkd->bqkgt", qq, k) / np.sqrt(d)
        msk = pos[None, :] <= pos[:, None]
        sc = jnp.where(msk[None, :, None, None, :], sc, -1e30)
        want = jnp.einsum("bqkgt,btkd->bqkgd", jax.nn.softmax(sc, -1),
                          v).reshape(b, s, h, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestMoE:
    def test_no_drop_combines_to_softmax_mixture(self):
        cfg = dataclasses.replace(get_arch("mixtral-8x7b").smoke,
                                  capacity_factor=50.0)
        lp = tfm.init_layer_params(cfg, KEY)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, cfg.d_model))
        out = tfm.moe_ffn(x, lp, cfg)
        # oracle: run every expert densely and mix by renormalized top-k
        logits = x @ lp["router"]
        probs = jax.nn.softmax(logits, -1)
        topv, topi = jax.lax.top_k(probs, cfg.top_k)
        topv = topv / topv.sum(-1, keepdims=True)
        h = jax.nn.silu(jnp.einsum("td,edf->tef", x, lp["w_gate"])) \
            * jnp.einsum("td,edf->tef", x, lp["w_up"])
        y_all = jnp.einsum("tef,efd->ted", h, lp["w_down"])
        want = jnp.einsum("tk,tkd->td", topv,
                          jnp.take_along_axis(
                              y_all, topi[:, :, None], axis=1))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_capacity_drops_tokens(self):
        cfg = dataclasses.replace(get_arch("mixtral-8x7b").smoke,
                                  capacity_factor=50.0)
        lp = tfm.init_layer_params(cfg, KEY)
        x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.d_model))
        full = tfm.moe_ffn(x, lp, cfg)
        tight = tfm.moe_ffn(x, lp, cfg, capacity=1)
        # capacity 1 must drop most assignments -> outputs differ
        assert float(jnp.abs(full - tight).max()) > 1e-3
        assert bool(jnp.isfinite(tight).all())


def test_chunked_xent_matches_full():
    rng = np.random.default_rng(0)
    b, s, d, v = 3, 17, 8, 29
    h = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = chunked_cross_entropy(h, head, labels, chunk=5)
    logits = h @ head
    lz = jax.nn.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lz - tgt)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@given(st.integers(1, 60), st.integers(1, 8), st.integers(1, 16))
@settings(max_examples=10, deadline=None)
def test_property_xent_any_shape(s, b, chunk):
    rng = np.random.default_rng(s * 31 + b)
    d, v = 6, 11
    h = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = chunked_cross_entropy(h, head, labels, chunk=chunk)
    logits = h @ head
    want = jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, labels[..., None],
                                          -1)[..., 0])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-5)
