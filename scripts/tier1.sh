#!/usr/bin/env bash
# Tier-1 verification — exactly the ROADMAP command; nonzero exit on any
# collection error or test failure. Works offline (no hypothesis needed).
set -euo pipefail
cd "$(dirname "$0")/.."
# repro-lint first: static invariants (launch discipline, kernel VMEM
# contracts, serving lock discipline) + BENCH_*.json schema — seconds,
# no kernels run, so structural regressions fail before the test matrix.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/lint_repro.py --bench-check
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# Kernel smoke: the ragged single-launch ELL path through the Pallas
# interpret-mode kernels on a small graph, WITH the contract-checked
# autotuner sweep — fails loudly on kernel regressions the pure-jnp
# test oracles could mask, and asserts the perf floor (>=1.3x over the
# pre-band baseline), single-launch, waste reduction, and that tuned
# outputs stay bitwise-equal to the defaults.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_spmm.py --dispatch ragged --smoke --autotune
# Scheduler smoke: deterministic serving-frontend simulation (synthetic
# arrival trace, SimClock, stub engine — zero real compiles) exercising
# every batch-closing rule, deadline accounting, admission control, and
# the shape-class lifecycle drift policy (retirement + drain barrier).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serving.py --smoke
# Pipelined-dispatch smoke: the same bursty near-capacity trace through
# serial AND pipelined dispatch on the overlap-modeling stub — asserts
# outputs bitwise-equal between modes, >=2x lower mean queue delay
# pipelined, zero added deadline misses, and the in-flight window bound.
# A third traced run writes a Perfetto JSON artifact; trace_report then
# re-derives the critical path from spans alone and --assert-complete
# fails the tier on any unclosed span tree or an overlap ratio that
# disagrees with the pipeline's own accounting by more than 10%.
TRACE_OUT="${TRACE_OUT:-$(mktemp -t tier1-trace-XXXXXX.json)}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serving.py --smoke --pipeline \
    --trace "$TRACE_OUT"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/trace_report.py "$TRACE_OUT" --assert-complete
# Replica smoke: the 1-vs-4 ReplicaSet comparison on simulated devices
# (same bursty trace, SimClock, zero real compiles) — asserts outputs
# bitwise-equal to single-replica, per-key order preserved under the
# key-epoch pin, >=3x aggregate throughput at 4 replicas, zero added
# deadline misses — plus the fault-injection rescue smoke (a replica
# dies mid-window: zero stranded futures, admission capacity shrinks).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serving.py --smoke --replicas 4
# Chaos smoke: end-to-end failure containment (docs/ROBUSTNESS.md) — a
# seeded fault plan fires every injection site (dispatch raise, compile
# failure, device hang, poisoned member, replica kill) over a bursty
# trace, then a flood trips the brownout. Asserts zero stranded
# futures, exactly the poisoned name fails (PoisonedRequest) with
# batch-mates bitwise-equal, per-key order preserved, a deterministic
# shed count, and guaranteed traffic served through the brownout.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serving.py --smoke --chaos
# Docs check: the serving API docstring examples actually run, and every
# internal link in README.md + docs/ resolves (files and anchors).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest --doctest-modules -q src/repro/serving
python scripts/check_docs.py
