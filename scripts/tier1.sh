#!/usr/bin/env bash
# Tier-1 verification — exactly the ROADMAP command; nonzero exit on any
# collection error or test failure. Works offline (no hypothesis needed).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
