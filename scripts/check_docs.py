#!/usr/bin/env python
"""Docs link checker (tier-1): every internal markdown link resolves.

Scans README.md and docs/**/*.md for markdown links `[text](target)`
and verifies:

  * relative file targets exist (resolved against the linking file);
  * `#anchor` fragments — both same-file (`#x`) and cross-file
    (`file.md#x`) — match a heading in the target file, using
    GitHub-style slugging (lowercase, spaces to dashes, punctuation
    dropped);
  * no link target is an absolute filesystem path.

External links (http/https/mailto) are intentionally NOT fetched: CI
must stay offline-deterministic. Exit 1 with a per-link report on any
failure.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("**/*.md"))]

# [text](target) — skips images' leading ! capture-wise (same rules apply)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: strip markup, lowercase, dash-join."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return re.sub(r" +", "-", text)


def anchors_of(path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(
        path.read_text(encoding="utf-8"))}


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text(encoding="utf-8")
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("/"):
            errors.append(f"{path.relative_to(ROOT)}: absolute path "
                          f"link {target!r}")
            continue
        file_part, _, anchor = target.partition("#")
        dest = (path if not file_part
                else (path.parent / file_part).resolve())
        if not dest.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link "
                          f"{target!r} (no such file)")
            continue
        if anchor and dest.suffix == ".md":
            if slugify(anchor) not in anchors_of(dest):
                errors.append(f"{path.relative_to(ROOT)}: broken anchor "
                              f"{target!r} (no matching heading)")
    return errors


def main() -> int:
    missing = [p for p in DOC_FILES if not p.exists()]
    if missing:
        for p in missing:
            print(f"docs check: required file missing: {p}")
        return 1
    errors = []
    n_links = 0
    for path in DOC_FILES:
        n_links += len(LINK_RE.findall(path.read_text(encoding="utf-8")))
        errors.extend(check_file(path))
    if errors:
        print(f"docs check: {len(errors)} broken link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs check OK: {len(DOC_FILES)} files, {n_links} links "
          f"(internal targets + anchors resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
