#!/usr/bin/env python
"""Critical-path report over an exported serving trace.

Usage:
    python scripts/trace_report.py TRACE.json [--assert-complete] [--json OUT]

Reads a Chrome-trace/Perfetto JSON file written by
``repro.obs.export.write_chrome_trace`` (e.g. via
``benchmarks/bench_serving.py --smoke --pipeline --trace TRACE.json``)
and prints, from spans alone: per-stage p50/p99, each request's
dominant stage, the measured staging/device overlap ratio cross-checked
against the pipeline's own ``overlap_ewma``/``overlap_ratio``, and
padded-MAC waste per shape class.

``--assert-complete`` exits nonzero unless every per-request span tree
is closed (no orphans, no unclosed spans, no ring wrap) AND the
span-measured overlap ratio lands within 10% of the ratio the pipeline
reported — the CI gate for the tier-1 trace artifact.
"""
import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import report as obs_report  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON file to analyze")
    ap.add_argument("--assert-complete", action="store_true",
                    help="exit 1 on incomplete span trees or an overlap "
                         "mismatch beyond 10%%")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the analysis bundle as JSON")
    args = ap.parse_args(argv)

    doc = obs_report.load_trace(args.trace)
    rep = obs_report.report(doc)
    print(obs_report.format_report(rep))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rep, fh, indent=1, sort_keys=True)

    if args.assert_complete:
        if rep["problems"]:
            print(f"FAIL: {len(rep['problems'])} completeness problem(s)",
                  file=sys.stderr)
            return 1
        if not rep["overlap"]["ok"]:
            print("FAIL: span-measured overlap disagrees with the "
                  "pipeline's reported ratio by more than 10%",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
