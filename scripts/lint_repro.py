#!/usr/bin/env python
"""repro-lint: static invariant checker for kernels, jaxprs, and
serving-thread discipline.

Three passes (see docs/STATIC_ANALYSIS.md for the full rule list):

  jaxpr        traces the fixture GCN executor on the pallas backend and
               checks launch discipline (exactly one ragged pallas_call
               per SpMM, zero fixed-K launches), absence of host-sync
               primitives in the traced region, dtype/shape flow from
               prepare_x padding through to logits, and the dead-lane
               proof that padded ELL slots cannot reach live output rows.
  kernel       recomputes VMEM footprints and index-map bounds from the
               kernel contracts in ``kernels/ell_spmm.py`` and
               ``kernels/tile_matmul.py``, and re-derives the shape-class
               fit oracle against the runtime's ``class_fits``.
  concurrency  AST lock-discipline audit over ``src/repro/serving`` and
               ``src/repro/engine``: worker-thread writes reachable from
               the public API without the owning lock, plus lock-order
               inversions against the declared hierarchy.

Benign races carry inline waivers — ``# lint: racy-ok(<reason>)`` on the
write or read line — which downgrade the finding to "waived" and are
listed under ``-v``.

Usage:
  PYTHONPATH=src python scripts/lint_repro.py                # all passes
  python scripts/lint_repro.py --passes kernel,concurrency
  python scripts/lint_repro.py --changed-only                # CI fast path
  python scripts/lint_repro.py --bench-check                 # + BENCH_*.json
  python scripts/lint_repro.py -v                            # show waivers

Exit status is 1 iff any unwaived error finding survives.
"""
from __future__ import annotations

import argparse
import fnmatch
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

ALL_PASSES = ("jaxpr", "kernel", "concurrency")

# --changed-only: which touched paths make which passes relevant. The
# jaxpr and kernel passes re-trace executors, so anything in the traced
# call graph (kernels, core, engine) triggers them; the concurrency pass
# scans engine + serving sources; the analysis package and this driver
# re-run everything (the checker itself changed).
CHANGED_MAP = (
    ("src/repro/kernels/*", {"jaxpr", "kernel"}),
    ("src/repro/core/*", {"jaxpr", "kernel"}),
    ("src/repro/engine/*", {"jaxpr", "kernel", "concurrency"}),
    ("src/repro/serving/*", {"concurrency"}),
    ("src/repro/obs/*", {"concurrency"}),
    ("src/repro/analysis/*", set(ALL_PASSES)),
    ("scripts/lint_repro.py", set(ALL_PASSES)),
    ("BENCH_*.json", {"bench"}),
)


def _git_changed(root: Path) -> list:
    """Paths changed vs the merge base with the main branch, plus any
    uncommitted / untracked work — i.e. "what this PR touches"."""
    def lines(*args):
        try:
            proc = subprocess.run(["git", *args], cwd=root, text=True,
                                  capture_output=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None
        if proc.returncode != 0:
            return None
        return [ln.strip() for ln in proc.stdout.splitlines() if ln.strip()]

    changed = set()
    # uncommitted + untracked
    status = lines("status", "--porcelain")
    for ln in status or []:
        changed.add(ln.split()[-1])
    # committed on this branch, if a base ref is resolvable
    for base in ("origin/main", "main"):
        mb = lines("merge-base", "HEAD", base)
        if mb:
            diff = lines("diff", "--name-only", f"{mb[0]}..HEAD")
            if diff is not None:
                changed.update(diff)
            break
    return sorted(changed)


def select_passes(changed: list) -> set:
    selected: set = set()
    for path in changed:
        for pattern, passes in CHANGED_MAP:
            if fnmatch.fnmatch(path, pattern):
                selected |= passes
    return selected


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="lint_repro",
        description="static invariant checker (jaxpr / kernel / "
                    "concurrency passes + BENCH_*.json schema)")
    ap.add_argument("--passes", default=",".join(ALL_PASSES),
                    help="comma-separated subset of "
                         f"{{{','.join(ALL_PASSES)}}}")
    ap.add_argument("--changed-only", action="store_true",
                    help="run only the passes whose inputs changed vs "
                         "the main branch (git); exits 0 immediately "
                         "when nothing relevant changed")
    ap.add_argument("--bench-check", action="store_true",
                    help="also validate BENCH_*.json trajectory files "
                         "at the repo root")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print waived findings and warnings")
    args = ap.parse_args(argv)

    requested = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in requested if p not in ALL_PASSES]
    if unknown:
        ap.error(f"unknown pass(es): {', '.join(unknown)}")

    bench = args.bench_check
    if args.changed_only:
        changed = _git_changed(ROOT)
        relevant = select_passes(changed)
        requested = [p for p in requested if p in relevant]
        bench = bench and ("bench" in relevant or bool(requested))
        if not requested and not bench:
            print("repro-lint: no relevant changes, skipping")
            return 0
        print(f"repro-lint: changed-only -> "
              f"{', '.join(requested) or 'bench only'}")

    # imports deferred so --changed-only can skip the jax import cost
    from repro.analysis.static.report import Report
    report = Report()
    for pass_name in requested:
        if pass_name == "jaxpr":
            from repro.analysis.static.jaxpr_pass import run_jaxpr_pass
            report.extend(run_jaxpr_pass())
        elif pass_name == "kernel":
            from repro.analysis.static.kernel_pass import run_kernel_pass
            report.extend(run_kernel_pass())
        elif pass_name == "concurrency":
            from repro.analysis.static.concurrency_pass import (
                run_concurrency_pass)
            report.extend(run_concurrency_pass())
    if bench:
        from repro.analysis.static.bench_check import check_bench_files
        report.extend(check_bench_files(ROOT))

    print(report.render(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
